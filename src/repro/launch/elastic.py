"""Elastic resize: rebuild a smaller/larger mesh and reshard state.

Fleet scenario: a host (8 chips) fails mid-run. The runbook is
  1. instant-restore the latest commit (manifest only, O(1)),
  2. rebuild the mesh from surviving hosts (drop a 'data' column — the mesh
     stays rectangular; the model axis is never shrunk since TP shards are
     intra-host),
  3. re-lower the step function for the new mesh; parameter/optimizer shards
     resize automatically because shardings are derived from the SAME logical
     rules on the new mesh,
  4. rescale the data plan: the global batch is kept by raising per-host
     batch (grad accumulation) or accepted-smaller with an LR rescale.

The deterministic per-shard data pipeline (data/pipeline.py) means surviving
hosts simply re-seed shard assignments — no data movement.

This module is exercised at test scale (8 -> 4 fake devices) in
tests/test_elastic.py; on a real fleet the same code runs per-coordinator.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import param_specs
from repro.parallel import sharding
from repro.train.steps import make_train_step


def shrink_mesh(mesh: Mesh, axis: str = "data", drop: int = 1) -> Mesh:
    """Rectangular mesh with `drop` slices removed from `axis`."""
    names = mesh.axis_names
    idx = names.index(axis)
    devs = mesh.devices
    keep = devs.shape[idx] - drop
    assert keep >= 1, "cannot shrink axis to zero"
    sl = [slice(None)] * devs.ndim
    sl[idx] = slice(0, keep)
    return Mesh(devs[tuple(sl)], names)


def relower_for_mesh(cfg, new_mesh: Mesh, rules: str = "train",
                     peak_lr: float = 3e-4):
    """Re-jit the train step for a resized mesh (shardings re-derived from
    the same logical rules)."""
    sharding.set_active(new_mesh, rules)
    return jax.jit(make_train_step(cfg, peak_lr=peak_lr), donate_argnums=(0,))


def reshard_tree(tree, new_mesh: Mesh, spec_tree, rules: str = "train"):
    """device_put existing arrays onto the resized mesh."""
    with sharding.use(new_mesh, rules):
        sh = sharding.tree_shardings(spec_tree, new_mesh, shape_tree=tree)
    return jax.device_put(tree, sh)


def rescale_batch_plan(global_batch: int, old_hosts: int, new_hosts: int):
    """Keep the global batch via per-host microbatching where divisible;
    otherwise return the nearest feasible batch + LR scale factor."""
    per_old = global_batch // old_hosts
    if global_batch % new_hosts == 0:
        return {"global_batch": global_batch,
                "per_host": global_batch // new_hosts,
                "accum_steps": max(1, (global_batch // new_hosts) // per_old),
                "lr_scale": 1.0}
    feasible = (global_batch // new_hosts) * new_hosts
    return {"global_batch": feasible, "per_host": feasible // new_hosts,
            "accum_steps": 1, "lr_scale": feasible / global_batch}
