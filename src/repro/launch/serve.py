"""Serving driver: ``python -m repro.launch.serve --arch <id>`` — batched
requests through the Dash prefix-cache engine (reduced config on CPU)."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=list(ARCH_IDS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--shared-prefix", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("serve demo targets token archs; use examples/")
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, cache_len=256, num_pages=256,
                           batch_size=4)

    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size, args.shared_prefix)
    reqs = []
    for i in range(args.requests):
        tail = rng.integers(1, cfg.vocab_size,
                            args.prompt_len - args.shared_prefix)
        reqs.append(Request(rid=i, prompt=np.concatenate([shared, tail]),
                            max_new_tokens=args.new_tokens))

    done = []
    for i in range(0, len(reqs), 4):
        done += engine.run(reqs[i:i + 4])
    stats = engine.prefix.stats
    print(f"[serve] {args.arch}: {len(done)} requests, "
          f"prefix hit rate {stats.hit_rate:.2%}, "
          f"prefill tokens saved {engine.flops_saved_tokens}, "
          f"dash load factor {engine.prefix.load_factor:.2f}")
    for r in done[:3]:
        print(f"  req {r.rid}: cached {r.cached_tokens} "
              f"prefilled {r.prefilled_tokens} -> {r.generated[:6]}...")
    return done


if __name__ == "__main__":
    main()
