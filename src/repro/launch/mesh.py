"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is the
    outer data-parallel axis crossing DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4):
    """Small host-device mesh for subprocess tests (8 fake devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
