"""End-to-end training driver: ``python -m repro.launch.train --arch <id>``.

Runs a real (reduced or full) config through the fault-tolerant trainer on
whatever devices exist — the same code path the dry-run lowers for 512 chips.
On this CPU container use ``--reduced`` (the smoke-scale config) with a small
step budget; see examples/train_lm.py for the ~100M-param recipe.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data import DedupFilter, PackedBatcher, PipelineConfig
from repro.train.trainer import Trainer, TrainerConfig


def batch_iter(cfg, batch_size: int, seq_len: int, dedup: bool):
    pc = PipelineConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                        batch_size=batch_size,
                        dup_fraction=0.05 if dedup else 0.0)
    batcher = PackedBatcher(pc, dedup=DedupFilter() if dedup else None)
    if cfg.family == "vlm":
        rng = np.random.default_rng(0)
        for b in batcher:
            P = cfg.num_patches
            yield {"tokens": b["tokens"], "labels": b["labels"],
                   "patch_embeds": rng.normal(
                       0, 1, (batch_size, P, cfg.d_model)).astype(np.float32)}
    elif cfg.family == "audio":
        rng = np.random.default_rng(0)
        for b in batcher:
            yield {"frame_embeds": rng.normal(
                0, 1, (batch_size, seq_len, cfg.d_model)).astype(np.float32),
                "labels": b["labels"] % cfg.vocab_size}
    else:
        yield from batcher


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--dedup", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    tcfg = TrainerConfig(total_steps=args.steps,
                         checkpoint_every=args.ckpt_every,
                         checkpoint_dir=args.ckpt_dir)
    it = batch_iter(cfg, args.batch, args.seq, args.dedup)
    trainer = Trainer(cfg, tcfg, it)
    if args.resume:
        resumed = trainer.resume_if_possible()
        if resumed is not None:
            print(f"[train] resumed from step {resumed}")
    result = trainer.run()
    losses = [m["loss"] for m in result["log"] if "loss" in m]
    print(f"[train] {args.arch} done: steps={result['final_step']} "
          f"first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f} "
          f"restarts={result['restarts']}")
    print(json.dumps({"first_loss": losses[0], "last_loss": losses[-1],
                      "stragglers": len(result["stragglers"])}))
    return result


if __name__ == "__main__":
    main()
