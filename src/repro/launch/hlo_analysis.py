"""Post-SPMD HLO analysis: trip-count-corrected FLOPs and collective bytes.

``compiled.cost_analysis()`` counts ``while`` (lax.scan) bodies ONCE, which
understates scanned-layer models by ~n_layers and flash-attention inner scans
by ~n_chunks. This module parses the partitioned HLO text, reconstructs the
computation call graph with while trip counts (from the loop-condition
constants), and accumulates:

  * dot FLOPs:  2 * prod(output dims) * prod(contracting dims), x multiplier
  * collective wire bytes per kind (ring-algorithm factors), x multiplier

Shapes in partitioned HLO are already per-device, so totals are per-device
quantities — exactly what the roofline terms want.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COMP_HDR = re.compile(r"^(ENTRY )?%?([\w\.\-]+)\s*\(.*\)\s*->")
_SHAPE_DEF = re.compile(r"%([\w\.\-]+)\s*=\s*\(?(\w+)\[([\d,]*)\]")
_PARAM_DEF = re.compile(r"%?([\w\.\-]+):\s*(\w+)\[([\d,]*)\]")
# Two operand spellings exist across XLA versions: bare names
# ``dot(%a, %b)`` and typed operands ``dot(f32[8,4096]{1,0} %a, ...)``.
# The optional type group captures the lhs dims inline when present (then no
# shapes-dict lookup is needed).
_DOT = re.compile(
    r"%([\w\.\-]+)\s*=\s*(\w+)\[([\d,]*)\][^=]*dot\("
    r"(?:(\w+)\[([\d,]*)\](?:\{[\d,]*\})?\s+)?%?([\w\.\-]+)"
    r".*?lhs_contracting_dims=\{([\d,]*)\}")
_COLL = re.compile(
    r"=\s+(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_TYPE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_WHILE = re.compile(r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_shard: float
    group: int


@dataclasses.dataclass
class Computation:
    name: str
    entry: bool = False
    dots: list = dataclasses.field(default_factory=list)       # flops (raw)
    colls: list = dataclasses.field(default_factory=list)      # CollectiveOp
    whiles: list = dataclasses.field(default_factory=list)     # (cond, body, trip|None)
    calls: list = dataclasses.field(default_factory=list)      # names
    consts: list = dataclasses.field(default_factory=list)     # ints seen


def _shape_elems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def parse_hlo(text: str):
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    shapes: dict[str, tuple] = {}

    for ln in text.splitlines():
        hdr = _COMP_HDR.match(ln) if (ln and not ln[0].isspace()) else None
        if hdr:
            cur = Computation(hdr.group(2), entry=bool(hdr.group(1)))
            comps[cur.name] = cur
            shapes = {}
            for pm in _PARAM_DEF.finditer(ln):
                shapes[pm.group(1)] = (pm.group(2),
                                       tuple(int(d) for d in pm.group(3).split(",") if d))
            continue
        if cur is None:
            continue
        sd = _SHAPE_DEF.search(ln)
        if sd:
            shapes[sd.group(1)] = (sd.group(2),
                                   tuple(int(d) for d in sd.group(3).split(",") if d))
        dm = _DOT.search(ln)
        if dm:
            out_elems = _shape_elems(dm.group(3))
            if dm.group(5) is not None:            # typed operand: dims inline
                lhs_dims = tuple(int(d) for d in dm.group(5).split(",") if d)
            else:
                lhs = shapes.get(dm.group(6))
                lhs_dims = lhs[1] if lhs is not None else ()
            contract = 1
            if dm.group(7):
                for ci in dm.group(7).split(","):
                    ci = int(ci)
                    if ci < len(lhs_dims):
                        contract *= lhs_dims[ci]
            cur.dots.append(2.0 * out_elems * contract)
        cm = _COLL.search(ln)
        if cm and cm.group(3) != "-done":
            # sum all result-tuple element sizes (tuple collectives are common)
            sz = 0
            for tm in _TYPE.finditer(cm.group(1)):
                sz += _DTYPE_BYTES.get(tm.group(1), 4) * _shape_elems(tm.group(2))
            n = None
            g = _GROUPS.search(ln)
            if g:
                n = len(g.group(1).split(","))
            else:
                g2 = _GROUPS_IOTA.search(ln)
                if g2:
                    n = int(g2.group(2))
            cur.colls.append(CollectiveOp(cm.group(2), float(sz), n or 2))
        wm = _WHILE.search(ln)
        if wm:
            tm = _TRIP.search(ln)
            cur.whiles.append((wm.group(1), wm.group(2),
                               int(tm.group(1)) if tm else None))
        for c in _CALLS.finditer(ln):
            cur.calls.append(c.group(1))
        for k in _CONST.finditer(ln):
            v = int(k.group(1))
            if 1 < v < 10_000_000:
                cur.consts.append(v)
    return comps


def _trip_count(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None or not cond.consts:
        return 1
    return max(cond.consts)


def analyze(text: str):
    """Returns dict with corrected per-device dot FLOPs and collective bytes."""
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.entry), None)
    if entry is None:
        return {"dot_flops": 0.0, "collectives": {}, "collective_counts": {}}

    flops_total = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_counts: dict[str, float] = defaultdict(float)

    seen_stack = []

    def visit(comp: Computation, mult: float):
        nonlocal flops_total
        if comp.name in seen_stack:      # recursion guard
            return
        seen_stack.append(comp.name)
        flops_total += mult * sum(comp.dots)
        for op in comp.colls:
            f = (op.group - 1) / op.group
            # sizes are RESULT sizes; reduce-scatter input = result * n
            wire = {"all-reduce": 2 * op.bytes_shard * f,
                    "all-gather": op.bytes_shard * f,
                    "reduce-scatter": op.bytes_shard * (op.group - 1),
                    "all-to-all": op.bytes_shard * f,
                    "collective-permute": op.bytes_shard}[op.kind]
            coll_bytes[op.kind] += mult * wire
            coll_counts[op.kind] += mult
        for cond, body, trip in comp.whiles:
            trip = trip if trip is not None else _trip_count(comps, cond)
            b = comps.get(body)
            if b is not None:
                visit(b, mult * trip)
        for callee in comp.calls:
            c = comps.get(callee)
            if c is not None and c.name != comp.name:
                visit(c, mult)
        seen_stack.pop()

    visit(entry, 1.0)
    return {"dot_flops": flops_total,
            "collectives": dict(coll_bytes),
            "collective_counts": dict(coll_counts),
            "n_computations": len(comps)}
