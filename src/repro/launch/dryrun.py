import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))
# ^ MUST run before any other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production mesh and extract the roofline terms.

For each cell:
  1. abstract params (eval_shape — zero allocation) + sharding specs,
  2. jit(train/prefill/serve step, in/out shardings).lower(abstract inputs),
  3. compiled = lowered.compile()    <- sharding coherence proof
  4. record compiled.cost_analysis() (HLO FLOPs/bytes),
     compiled.memory_analysis() (per-device footprint; analytic fallback),
     and collective bytes parsed from the post-SPMD HLO text
     (all-gather / all-reduce / reduce-scatter / all-to-all /
      collective-permute with ring-algorithm wire-byte factors).

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json; the roofline
report (benchmarks/roofline.py) and EXPERIMENTS.md read from there.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, SHAPES, get_config, input_specs,
                           shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import (abstract_params, decode_state_specs,
                                      param_specs)
from repro.optim import adamw
from repro.parallel import sharding
from repro.train.steps import (TrainState, make_prefill_step, make_serve_step,
                               make_train_step)

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def parse_collectives(hlo_text: str):
    """Sum wire bytes per collective kind from post-SPMD HLO.

    Ring-algorithm accounting per participating device group of size n:
      all-reduce:        2 * bytes * (n-1)/n
      all-gather:        bytes_out * (n-1)/n
      reduce-scatter:    bytes_in  * (n-1)/n
      all-to-all:        bytes * (n-1)/n
      collective-permute: bytes
    """
    totals = {}
    counts = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _COLL_RE.search(ln)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        bytes_el = _DTYPE_BYTES.get(dtype)
        if bytes_el is None:
            continue
        size = bytes_el
        if dims:
            for d in dims.split(","):
                size *= int(d)
        n = None
        g = _GROUPS_RE.search(ln)
        if g:
            n = len(g.group(1).split(","))
        else:
            g2 = _GROUPS_IOTA_RE.search(ln)
            if g2:
                n = int(g2.group(2))
        n = n or 2
        f = (n - 1) / n
        wire = {"all-reduce": 2 * size * f, "all-gather": size * f,
                "reduce-scatter": size * f, "all-to-all": size * f,
                "collective-permute": float(size)}[kind]
        totals[kind] = totals.get(kind, 0.0) + wire
        counts[kind] = counts.get(kind, 0) + 1
    return totals, counts


def _spec_bytes(tree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree.leaves(tree)))


def _sharded_bytes(tree, shardings, mesh) -> int:
    """Analytic per-device bytes for (abstract tree, shardings)."""
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 0
    for x, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(
            shardings, is_leaf=lambda s: hasattr(s, "spec"))):
        shards = 1
        for ax in jax.tree.leaves(tuple(sh.spec)):
            if ax is not None:
                shards *= axis_size[ax]
        total += int(np.prod(x.shape)) * x.dtype.itemsize // shards
    return total


def _bf16_params(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, jnp.bfloat16 if x.dtype == jnp.float32 else x.dtype), tree)


def lower_cell(arch: str, shape: str, mesh, rules: str | None = None):
    """Returns (lowered, aux dict with analytic byte counts)."""
    cfg = get_config(arch)
    case = SHAPES[shape]
    spec = input_specs(cfg, shape)
    pshapes, pspecs = abstract_params(cfg)

    if case.kind == "train":
        # Single-pod trains default to the pure-DP(ZeRO-3) + shard_map-MoE
        # layout: 3-18x collective wins over TP+SP across every family
        # (EXPERIMENTS.md SSPerf). The multipod mesh keeps TP+SP: the
        # assigned global batch (256) is smaller than 512 chips, so pure DP
        # would duplicate compute across the model axis — with production
        # batches (>= chips) train_dp extends to multipod via the pod axis.
        multi = "pod" in mesh.axis_names
        if rules is None:
            if multi:
                rules = "train_multi_moe" if cfg.family == "moe" else "train"
            elif cfg.family == "moe" and cfg.n_experts % 16 == 0:
                rules = "train_dp_ep"   # true EP (compute-bound; SSPerf)
            else:
                rules = "train_dp"
        with sharding.use(mesh, rules):
            from jax.sharding import NamedSharding, PartitionSpec as P
            p_sh = sharding.tree_shardings(pspecs, mesh, shape_tree=pshapes)
            opt_abs = jax.eval_shape(adamw.init, pshapes)
            repl = NamedSharding(mesh, P())
            opt_sh = adamw.AdamWState(m=p_sh, v=p_sh, count=repl)
            state_abs = TrainState(pshapes, opt_abs, jax.ShapeDtypeStruct((), jnp.int32))
            state_sh = TrainState(p_sh, opt_sh, repl)
            bspec = {k: sharding.spec_for(("batch",) + (None,) * (len(v.shape) - 1),
                                          dims=tuple(v.shape))
                     for k, v in spec["batch"].items()}
            b_sh = {k: NamedSharding(mesh, s) for k, s in bspec.items()}
            met_sh = repl
            step = make_train_step(cfg)
            jitted = jax.jit(step, in_shardings=(state_sh, b_sh),
                             out_shardings=(state_sh, met_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, spec["batch"])
            static_bytes = _sharded_bytes(pshapes, p_sh, mesh) * 3  # params+m+v
        return lowered, {"static_bytes_per_device": static_bytes, "rules": rules}

    if case.kind == "prefill":
        # prefill shards like the training fwd (SP); MoE uses dense-MoE rules
        rules = rules or ("prefill_moe" if cfg.family == "moe" else "train")
        with sharding.use(mesh, rules):
            from jax.sharding import NamedSharding
            p_abs = _bf16_params(pshapes)
            p_sh = sharding.tree_shardings(pspecs, mesh, shape_tree=p_abs)
            bspec = {k: sharding.spec_for(("batch",) + (None,) * (len(v.shape) - 1),
                                          dims=tuple(v.shape))
                     for k, v in spec["batch"].items()}
            b_sh = {k: NamedSharding(mesh, s) for k, s in bspec.items()}
            lspec = sharding.spec_for(("batch", "seq", "vocab"))
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=NamedSharding(mesh, lspec))
            lowered = jitted.lower(p_abs, spec["batch"])
            static_bytes = _sharded_bytes(p_abs, p_sh, mesh)
        return lowered, {"static_bytes_per_device": static_bytes, "rules": rules}

    # decode
    rules = rules or ("decode_b1" if case.global_batch == 1 else "decode")
    with sharding.use(mesh, rules):
        from jax.sharding import NamedSharding, PartitionSpec as P
        p_abs = _bf16_params(pshapes)
        p_sh = sharding.tree_shardings(pspecs, mesh, shape_tree=p_abs)
        sspecs = decode_state_specs(cfg)
        s_sh = sharding.tree_shardings(sspecs, mesh, shape_tree=spec["state"])
        i_sh = {k: NamedSharding(mesh, sharding.spec_for(
            ("batch",) + (None,) * (len(v.shape) - 1), dims=tuple(v.shape)))
            for k, v in spec["inputs"].items()}
        logit_sh = NamedSharding(mesh, sharding.spec_for(
            ("batch", "vocab"), dims=(case.global_batch, cfg.vocab_size)))
        step = make_serve_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_sh, s_sh, i_sh),
                         out_shardings=(logit_sh, s_sh), donate_argnums=(1,))
        lowered = jitted.lower(p_abs, spec["state"], spec["inputs"])
        static_bytes = (_sharded_bytes(p_abs, p_sh, mesh)
                        + _sharded_bytes(spec["state"], s_sh, mesh))
    return lowered, {"static_bytes_per_device": static_bytes, "rules": rules}


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Path,
             rules: str | None = None, save_hlo: bool = False):
    mesh_name = "multipod" if multi_pod else "pod"
    cfg = get_config(arch)
    if not shape_applicable(cfg, shape):
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "skipped",
               "reason": "long_500k needs sub-quadratic attention "
                         "(full-attention arch; DESIGN.md SS5)"}
        _write(out_dir, mesh_name, arch, shape, rec)
        print(f"[dryrun] {arch} x {shape} x {mesh_name}: SKIP (full attention)")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        lowered, aux = lower_cell(arch, shape, mesh, rules)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        try:
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
        except Exception as e:            # pragma: no cover
            cost = {"error": str(e)}
        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            }
        except Exception as e:            # pragma: no cover
            mem_rec = {"error": str(e)}

        hlo = compiled.as_text()
        from repro.launch import hlo_analysis
        res = hlo_analysis.analyze(hlo)

    n_dev = 512 if multi_pod else 256
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "status": "ok",
        "rules": aux["rules"], "n_devices": n_dev,
        # raw cost_analysis (while bodies counted once) + corrected dot flops
        "flops_raw": cost.get("flops"),
        "bytes_raw": cost.get("bytes accessed"),
        "dot_flops_per_device": res["dot_flops"],
        "memory_analysis": mem_rec,
        "static_bytes_per_device": aux["static_bytes_per_device"],
        "collective_wire_bytes": res["collectives"],
        "collective_counts": res["collective_counts"],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_bytes": len(hlo),
    }
    if save_hlo:
        (out_dir / mesh_name).mkdir(parents=True, exist_ok=True)
        (out_dir / mesh_name / f"{arch}__{shape}.hlo.txt").write_text(hlo)
    _write(out_dir, mesh_name, arch, shape, rec)
    print(f"[dryrun] {arch} x {shape} x {mesh_name}: OK "
          f"dotflops={res['dot_flops']:.3e} colls={sum(res['collective_counts'].values()):.0f} "
          f"static={aux['static_bytes_per_device']/2**30:.2f}GiB/dev "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return rec


def _write(out_dir: Path, mesh_name: str, arch: str, shape: str, rec: dict):
    d = out_dir / mesh_name
    d.mkdir(parents=True, exist_ok=True)
    (d / f"{arch}__{shape}.json").write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--rules", default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    out = Path(args.out)

    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multipod"]

    failures = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                tag = f"{a}__{s}.json"
                if args.skip_existing and (
                        out / ("multipod" if mp else "pod") / tag).exists():
                    continue
                try:
                    run_cell(a, s, mp, out, args.rules, args.save_hlo)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((a, s, mp, str(e)))
                    _write(out, "multipod" if mp else "pod", a, s,
                           {"arch": a, "shape": s, "status": "error",
                            "error": str(e)})
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("[dryrun] all requested cells passed")


if __name__ == "__main__":
    main()
