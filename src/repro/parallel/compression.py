"""Gradient compression for the DP all-reduce: int8 quantization with error
feedback (1-bit-Adam-family trick, DCN-friendly).

The pod axis of the production mesh crosses DCN, where the gradient
all-reduce of a 6-42B model (24-168 GB fp32) dominates step time. Per-tensor
symmetric int8 quantization cuts wire bytes 4x; the quantization error is
carried in a residual buffer and added back next step (error feedback), which
keeps convergence within noise for smooth objectives.

Usage: inside a shard_map over the DP axis —
    grads, residual = compressed_psum(grads, residual, axis_name="pod")

Integration point: the trainer's ``grad_sync="int8"`` mode wraps the gradient
tree before the optimizer; the dry-run comparison (4x collective-term
reduction on the pod axis) is part of the EXPERIMENTS.md perf log.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, residuals, axis_name: str):
    """int8 + error-feedback psum over ``axis_name``.

    Each leaf: e = g + residual; q = int8(e); psum(q) (wire = 1 byte/elem);
    new residual = e - dequant(q). Scales are psum-maxed (tiny)."""

    def one(g, r):
        e = g.astype(jnp.float32) + r
        q, scale = _quantize(e)
        # share a common scale so the integer sum is well-defined
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(e / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        mean = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
        new_r = e - _dequantize(q, scale)
        return mean.astype(g.dtype), new_r

    out = jax.tree.map(one, grads, residuals)
    new_grads = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_res


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


# ---------------------------------------------------------------------------
# int8 FSDP weight gather (straight-through)
# ---------------------------------------------------------------------------

import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def fsdp_gather_int8(w_shard, axes, gather_axis, out_dtype):
    """All-gather an FSDP weight shard in int8 (4x less wire than fp32, 2x
    less than bf16), dequantizing with per-(shard, out-channel) scales.

    Backward is the exact ZeRO grad sync: reduce-scatter of the (bf16)
    output gradient back to the shard (straight-through estimator across the
    quantization — standard for comms quantization of *weights*, where the
    rounding perturbation is a forward-noise term, not a gradient path)."""
    return _gather_int8_fwd_impl(w_shard, axes, gather_axis, out_dtype)


def _gather_int8_fwd_impl(w_shard, axes, gather_axis, out_dtype):
    scale = jnp.max(jnp.abs(w_shard), axis=gather_axis, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(w_shard / scale), -127, 127).astype(jnp.int8)
    qg = jax.lax.all_gather(q, axes, axis=gather_axis, tiled=True)
    sg = jax.lax.all_gather(scale.astype(jnp.float32), axes,
                            axis=gather_axis, tiled=True)
    n_shards = qg.shape[gather_axis] // q.shape[gather_axis]
    # broadcast each shard's scale over its block of the gathered axis
    reps = qg.shape[gather_axis] // sg.shape[gather_axis]
    sg = jnp.repeat(sg, reps, axis=gather_axis)
    return (qg.astype(jnp.float32) * sg).astype(out_dtype)


def _gather_int8_fwd(w_shard, axes, gather_axis, out_dtype):
    return _gather_int8_fwd_impl(w_shard, axes, gather_axis, out_dtype), None


def _gather_int8_bwd(axes, gather_axis, out_dtype, _, g):
    g_shard = jax.lax.psum_scatter(g.astype(jnp.bfloat16), axes,
                                   scatter_dimension=gather_axis, tiled=True)
    return (g_shard.astype(jnp.float32),)


fsdp_gather_int8.defvjp(_gather_int8_fwd, _gather_int8_bwd)


def wire_bytes(tree, compressed: bool) -> int:
    """Analytic wire bytes of one DP sync (for the perf log)."""
    import numpy as np
    elems = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
    return elems * (1 if compressed else 4)
