"""Logical-axis sharding rules (MaxText-style) for params and activations.

Params/activations carry *logical* axis names; a rule set maps them to mesh
axes ('pod', 'data', 'model'). Presets (chosen for the production mesh
(data=16, model=16) [+ pod=2], with divisibility across all 10 archs):

  * ``train``    — baseline training: FSDP + TP + SP.
                   batch->('pod','data'), activation seq->'model' (Megatron
                   sequence parallelism: the scan carry is 1/16th per chip,
                   which is what lets 4k x 256 fit v5e HBM), param embed dim
                   ->'data' (ZeRO-3: per-layer all-gather under the scan),
                   heads/kv/mlp/vocab/rnn->'model'. Expert dim is REPLICATED
                   and expert FFNs shard on their mlp dim — uniform across 8-
                   and 16-expert archs on a 16-way axis (see DESIGN.md).
  * ``train_tp`` — pure TP+SP (no FSDP) — hillclimb comparison point.
  * ``train_ep`` — expert-parallel MoE (expert->'model'); valid only when
                   n_experts % model == 0 (phi3.5's 16) — hillclimb option.
  * ``decode``   — serving: batch->('pod','data'), KV-cache length->'model'
                   (keeps the 32k cache ~1-3 GB/chip), kv heads replicated
                   (GQA counts of 1/2/4/8 don't divide 16), params TP on
                   projection dims.
  * ``decode_b1``— single-sequence long-context decode: batch unsharded,
                   window/cache->'data', heads->'model'.

The active (mesh, rules) pair is process-global, installed by the launcher;
model code calls ``logical_constraint`` which is a no-op outside a mesh so
smoke tests run unsharded on one device.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _mk(**over):
    base = {
        "batch": ("pod", "data"), "seq": None, "act_embed": None,
        "act_heads": "model", "act_kv": "model", "embed": None,
        "heads": "model", "kv": "model",
        "head_dim": None, "mlp": "model", "vocab": "model", "expert": None,
        "rnn": "model", "layers": None, "kv_heads": None, "cache": None,
    }
    base.update(over)
    return base


_PRESETS = {
    "train": _mk(seq="model", embed="data"),
    "train_tp": _mk(seq="model"),
    "train_tp_nosp": _mk(),
    "train_ep": _mk(seq="model", embed="data", expert="model", mlp=None),
    # pure ZeRO-3: batch over BOTH intra-pod axes (B_loc=1), params sharded
    # over both, zero TP/SP traffic. Wins whenever per-layer activation
    # collectives exceed param gathers (measured 14-19x on MoE train cells —
    # EXPERIMENTS.md SSPerf); 'pod' stays outer DP for the multipod mesh.
    "train_dp": _mk(batch=("pod", "data", "model"), seq=None,
                    embed=("data", "model"), heads=None, kv=None, mlp=None,
                    vocab=None, rnn=None, act_heads=None, act_kv=None,
                    _moe_shardmap=True),
    # true expert parallelism: experts owned by 'model' ranks (needs
    # n_experts % 16 == 0, e.g. phi3.5's 16), FSDP over 'data', pure-DP
    # batch. Tokens a2a to their experts instead of gathering expert weights.
    "train_dp_ep": _mk(batch=("pod", "data", "model"), seq=None,
                       embed=("data",), expert="model", heads=None, kv=None,
                       mlp=None, vocab=None, rnn=None, act_heads=None,
                       act_kv=None, _moe_ep=True),
    "decode": _mk(cache="model", _moe_dense=True),
    "decode_b1": _mk(batch=None, cache="data", _moe_dense=True),
    # MoE prefill: TP+SP like 'train' but with dispatch-free dense MoE
    "prefill_moe": _mk(seq="model", embed="data", _moe_dense=True),
    # multipod MoE training: global batch (256) < chips (512) rules out the
    # pure-DP shard_map layout, and SPMD dispatch under TP replicates
    # (SSPerf H1-H6) — dense-MoE gives the known-good TP schedule at E/k
    # extra expert FLOPs. Seq-aware shard_map dispatch is logged future work.
    "train_multi_moe": _mk(seq="model", embed="data", _moe_dense=True),
}

_ACTIVE = {"mesh": None, "rules": _PRESETS["train"]}


def presets():
    return dict(_PRESETS)


def set_active(mesh: Optional[Mesh], rules="train"):
    _ACTIVE["mesh"] = mesh
    _ACTIVE["rules"] = _PRESETS[rules] if isinstance(rules, str) else rules


@contextlib.contextmanager
def use(mesh: Optional[Mesh], rules="train"):
    prev = dict(_ACTIVE)
    set_active(mesh, rules)
    try:
        yield
    finally:
        _ACTIVE.update(prev)


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE["mesh"]


def flag(name: str) -> bool:
    """Non-axis boolean flags carried in the rules dict (keys start with _)."""
    return bool(_ACTIVE["rules"].get(name, False))


def axes_for(name: str, dim: int | None = None) -> tuple:
    """The mesh axes logical ``name`` resolves to (dims-aware, with the same
    prefix-fallback as tensor sharding)."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return ()
    spec = _resolve((name,), dims=(dim,) if dim is not None else None)
    m = spec[0] if len(spec) else None
    if m is None:
        return ()
    return m if isinstance(m, tuple) else (m,)


def batch_axes(dim: int | None = None) -> tuple:
    """Mesh axes the 'batch' logical axis maps to (dims-aware)."""
    return axes_for("batch", dim)


def _divisible(dim: Optional[int], n: int) -> bool:
    return dim is None or dim % n == 0


def _resolve(names, rules=None, mesh=None, dims=None) -> P:
    """Map logical names -> PartitionSpec, dropping axes that are absent from
    the mesh, already used, or that don't divide the tensor dim."""
    rules = rules or _ACTIVE["rules"]
    mesh = mesh or _ACTIVE["mesh"]
    axes = []
    used = set()
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else None
    for i, n in enumerate(names):
        dim = None if dims is None else dims[i]
        if n is None:
            axes.append(None)
            continue
        m = rules.get(n)
        if isinstance(m, tuple):
            m = tuple(a for a in m
                      if (mesh_shape is None or a in mesh_shape) and a not in used)
            if m and mesh_shape is not None:
                # longest PREFIX whose axis product divides the dim (e.g.
                # batch 256 on (pod,data,model)=512 falls back to
                # (pod,data)=32 on the multipod mesh)
                while m:
                    total = 1
                    for a in m:
                        total *= mesh_shape[a]
                    if _divisible(dim, total):
                        break
                    m = m[:-1]
            m = m if m else None
        elif m is not None and mesh_shape is not None:
            if m not in mesh_shape or m in used or not _divisible(dim, mesh_shape[m]):
                m = None
        elif m is not None and m in used:
            m = None
        if m is not None:
            used.update(m if isinstance(m, tuple) else [m])
        axes.append(m)
    return P(*axes)


def spec_for(names, rules=None, mesh=None, dims=None) -> P:
    """PartitionSpec for a tuple of logical axis names."""
    return _resolve(tuple(names), rules, mesh, dims)


def tree_specs(spec_tree, rules=None, mesh=None, shape_tree=None):
    """Map a pytree of logical-name-tuples to PartitionSpecs. If shape_tree
    is given (matching pytree of ShapeDtypeStructs/arrays), axes that don't
    divide the corresponding dim are dropped (e.g. kv=4 heads on a 16 axis)."""
    is_names = lambda x: isinstance(x, tuple)
    if shape_tree is None:
        return jax.tree.map(lambda names: _resolve(tuple(names), rules, mesh),
                            spec_tree, is_leaf=is_names)
    return jax.tree.map(
        lambda names, arr: _resolve(tuple(names), rules, mesh,
                                    dims=tuple(arr.shape)),
        spec_tree, shape_tree, is_leaf=is_names)


def tree_shardings(spec_tree, mesh=None, rules=None, shape_tree=None):
    mesh = mesh or _ACTIVE["mesh"]
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(spec_tree, rules, mesh, shape_tree),
                        is_leaf=lambda x: isinstance(x, P))


def logical_constraint(x, names):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _resolve(tuple(names), dims=tuple(x.shape))))


def shard_specs(axes, tree):
    """PartitionSpecs for a stacked per-shard pytree: every leaf carries the
    shard dimension first and shards over ``axes`` (the distributed DHT's
    state layout — one Dash table per device, stacked on dim 0)."""
    axes = tuple(axes)
    return jax.tree.map(lambda _: P(axes), tree)
