"""Distribution substrate: logical-axis sharding rules + gradient compression."""
from . import sharding

__all__ = ["sharding"]
