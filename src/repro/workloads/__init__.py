"""Workload generators that drive the serving frontend end-to-end."""
from . import ycsb
from .ycsb import MIXES, YCSBConfig, generate, load_keys, zipfian_ranks

__all__ = ["ycsb", "MIXES", "YCSBConfig", "generate", "load_keys",
           "zipfian_ranks"]
