"""YCSB-style workload generator for the Dash serving frontend.

The paper evaluates Dash under the standard mixed key-value workloads
(Sec. 6, Fig. 7/8/12/13); this module generates the same op mixes as
streams of ``serving.frontend.Op`` so the concurrent frontend — and the
stop-the-world baseline — can be driven end-to-end.

Mix -> paper-figure mapping (what each one stresses):

  =====  ======================  =====================================
  mix    op ratio                paper analog
  =====  ======================  =====================================
  A      50% read / 50% update   Fig. 8 "mixed" scalability runs: the
                                 update-heavy contention case (bucket
                                 version churn -> verify-retry rate)
  B      95% read / 5% update    Fig. 13 optimistic-read regime: reads
                                 dominate, writes still bump versions
  C      100% read               Fig. 7/9 pure probe throughput — the
                                 fingerprint read path alone
  D      95% read / 5% insert,   Fig. 12 load-factor growth: fresh keys
         reads skew to latest    drive fills (and eventually splits)
  E      95% multi-get(scan      range workload; Dash has no ordered
         analog) / 5% insert     scan, so E issues short multi-key
                                 lookup bursts (documented deviation)
  F      50% read / 50% RMW      Alg. 1 insert/update path under
                                 read-modify-write dependencies
  load   100% insert             Fig. 12 fill / split-storm driver —
                                 the online-resize benchmark's storm
  =====  ======================  =====================================

Key selection: ``uniform`` or ``zipfian`` (independent-draw approximation
of the YCSB scrambled-zipfian, theta=0.99 by default) over the loaded key
space; workload D draws read keys from the most recently inserted window
("latest" distribution).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.serving.frontend import DELETE, INSERT, READ, RMW, UPDATE, Op

#: kind ratios per mix: (read, update, insert, rmw)
MIXES = {
    "A": {READ: 0.5, UPDATE: 0.5},
    "B": {READ: 0.95, UPDATE: 0.05},
    "C": {READ: 1.0},
    "D": {READ: 0.95, INSERT: 0.05},
    "E": {READ: 0.95, INSERT: 0.05},     # multi-get bursts, see generate()
    "F": {READ: 0.5, RMW: 0.5},
    "load": {INSERT: 1.0},
}

#: YCSB-E scan-analog burst length (keys per multi-get)
SCAN_LEN = 8


@dataclasses.dataclass
class YCSBConfig:
    mix: str = "A"
    n_ops: int = 4096
    distribution: str = "zipfian"      # "uniform" | "zipfian" | "latest"
    zipf_theta: float = 0.99
    seed: int = 0


def zipfian_ranks(rng: np.random.Generator, n: int, size: int,
                  theta: float = 0.99) -> np.ndarray:
    """Independent draws of ranks in [0, n) with the YCSB zipfian weights
    p(r) ~ 1/(r+1)^theta (exact CDF inversion over the finite key space;
    YCSB's scrambled-zipfian then hashes ranks over the space — callers
    index an already-shuffled key array, which is the same scrambling)."""
    if n <= 0:
        return np.zeros(size, dtype=np.int64)
    w = 1.0 / np.power(np.arange(1, n + 1), theta)
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    return np.searchsorted(cdf, rng.random(size)).clip(0, n - 1)


def load_keys(rng: np.random.Generator, n: int) -> np.ndarray:
    """A shuffled unique key space (shuffling doubles as the scrambled-
    zipfian hash: rank r -> a pseudo-random key)."""
    out = np.unique(rng.integers(1, 2 ** 63, size=int(n * 2.2) + 16,
                                 dtype=np.uint64))
    assert out.size >= n
    keys = out[:n]
    rng.shuffle(keys)
    return keys


def generate(cfg: YCSBConfig, loaded_keys: np.ndarray,
             insert_keys: Optional[np.ndarray] = None) -> List[Op]:
    """Materialize ``cfg.n_ops`` frontend Ops (E's scan bursts count
    toward the budget, so op streams are size-comparable across mixes;
    fewer only if the insert budget and loaded space are both exhausted).

    ``loaded_keys`` is the pre-loaded key space reads/updates draw from
    (may be empty for the pure-insert ``load`` mix); ``insert_keys``
    supplies fresh keys for insert-bearing mixes (D/E/load) in order.
    Workload D reads skew half to the latest inserted window (its YCSB
    definition); ``distribution="latest"`` applies that skew to every
    read. E's "scans" are SCAN_LEN consecutive multi-get reads. Values
    are derived from the key so correctness checks need no side table
    (``expected_value``)."""
    if cfg.mix not in MIXES:
        raise ValueError(f"unknown mix {cfg.mix!r} (have {sorted(MIXES)})")
    rng = np.random.default_rng(cfg.seed)
    ratios = MIXES[cfg.mix]
    kinds = list(ratios)
    probs = np.asarray([ratios[k] for k in kinds])
    draws = rng.choice(len(kinds), size=cfg.n_ops, p=probs / probs.sum())

    n = loaded_keys.size
    if cfg.distribution == "zipfian":
        ranks = zipfian_ranks(rng, n, cfg.n_ops, cfg.zipf_theta)
    else:
        ranks = rng.integers(0, n, cfg.n_ops) if n else np.zeros(
            cfg.n_ops, dtype=np.int64)

    needs_inserts = any(k == INSERT for k in kinds)
    if needs_inserts:
        assert insert_keys is not None, f"mix {cfg.mix} needs insert_keys"
    inserted: List[int] = []
    next_insert = 0
    ops: List[Op] = []
    for i, d in enumerate(draws):
        if len(ops) >= cfg.n_ops:
            break
        kind = kinds[d]
        if kind == INSERT:
            if next_insert >= len(insert_keys):
                kind = READ               # key budget spent: degrade to read
                if n == 0:
                    break                 # nothing loaded to read either
            else:
                key = int(insert_keys[next_insert])
                next_insert += 1
                inserted.append(key)
                ops.append(Op(INSERT, key, expected_value(key)))
                continue
        latest = inserted and (cfg.distribution == "latest"
                               or (cfg.mix == "D" and rng.random() < 0.5))
        if kind == READ and latest:
            # "latest" distribution: reads chase the insert front
            key = inserted[-1 - int(rng.integers(0, min(64, len(inserted))))]
            ops.append(Op(READ, key))
            continue
        if kind == READ and cfg.mix == "E":
            # scan analog: a burst of consecutive keys from the loaded space
            start = int(ranks[i])
            for j in range(min(SCAN_LEN, cfg.n_ops - len(ops))):
                ops.append(Op(READ, int(loaded_keys[(start + j) % n])))
            continue
        key = int(loaded_keys[ranks[i]])
        if kind == READ:
            ops.append(Op(READ, key))
        elif kind == UPDATE:
            ops.append(Op(UPDATE, key, updated_value(key)))
        elif kind == RMW:
            ops.append(Op(RMW, key, updated_value(key)))
        else:                              # pragma: no cover - DELETE unused
            ops.append(Op(DELETE, key))
    return ops


def expected_value(key: int) -> int:
    """Load-phase value for a key (derived, so checks need no side table)."""
    return (key ^ (key >> 17)) & 0x7FFFFFFF or 1


def updated_value(key: int) -> int:
    return (expected_value(key) + 0x9E37) & 0x7FFFFFFF or 1
