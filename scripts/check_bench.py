#!/usr/bin/env python
"""Bench-regression gate: check ``BENCH_*.json`` artifacts against their
acceptance bounds and against the last committed run.

Two checks, one per invocation mode:

``--self``
    Every artifact in the working tree satisfies its ABSOLUTE acceptance
    bounds — the same gates the bench modules assert before writing the
    JSON, re-checked from the artifact so CI catches a hand-edited or
    stale-schema file without re-running a 4-minute bench.

default (regression)
    Working-tree artifacts vs the committed baseline (``git show
    REF:artifact``): headline fields may not be WORSE than the baseline by
    more than a tolerance. Tolerances are wide (1-core container, noisy
    wall clocks) — this catches step-function regressions (a gate ratio
    doubling), not percent-level noise. Ratio-of-ratio fields use
    multiplicative tolerance; counts must match exactly.

Exit status 0 = all checks pass; 1 = violation (each printed); missing
artifacts or a missing baseline are SKIPPED with a note (first run of a
new bench has no baseline to regress against).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# -- absolute acceptance bounds (mirror of each bench's asserts) -------------
# (artifact, dotted field, op, bound); op: "<=", ">=", "=="
GATES = [
    ("BENCH_online_resize.json", "p99_ratio", "<=", 0.5),
    ("BENCH_online_resize.json", "frontend.publish_volume_ratio", "<=", 0.25),
    ("BENCH_online_resize.json", "frontend.hint_misses", "==", 0),
    ("BENCH_online_resize.json", "frontend.read_sojourn_hist.n", ">=", 1),
    ("BENCH_batch_parallel.json", "latency_256.insert_fused_vs_scan_p50",
     ">=", 1.5),
    ("BENCH_batch_parallel.json", "latency_256.search_fused_vs_vmap_p50",
     ">=", 1.0),
    ("BENCH_durable_restart.json", "ttfq_spread", "<=", 2.0),
    ("BENCH_durable_restart.json", "storm.volume_ratio", "<=", 0.25),
    ("BENCH_durable_restart.json", "storm.staged_ratio", "<=", 0.25),
    ("BENCH_durable_restart.json", "storm.flush_hint_misses", "==", 0),
    ("BENCH_durable_restart.json", "checksummed_reopen.ratio", "<=", 1.5),
    ("BENCH_chaos.json", "matrix.wrong_reads", "==", 0),
    ("BENCH_chaos.json", "matrix.silent_lost", "==", 0),
    ("BENCH_chaos.json", "matrix.indeterminate_pending", "==", 0),
    # ISSUE-9: device-resident DHT hot path vs host-mirror baseline
    ("BENCH_dht_parallel.json", "verify.p99_ratio", "<=", 0.5),
    ("BENCH_dht_parallel.json", "verify.host_plane_bytes", "==", 0),
    ("BENCH_dht_parallel.json", "splits.speedup", ">=", 2.0),
    ("BENCH_dht_parallel.json", "reopen.ttfq_ratio", "<=", 1.5),
    ("BENCH_dht_parallel.json", "hist_agree.n", ">=", 1),
    ("BENCH_dht_parallel.json", "hist_agree.p99_err", "<=", 0.10),
    # DHT roofline: right-sized routing lanes keep per-device fabric bytes
    # at the same order as the local HBM probe term (~82KB vs ~90KB at 1024
    # q/dev; a lane-sizing regression would blow this up 16x)
    ("BENCH_dht_roofline.json", "n_shards", ">=", 256),
    ("BENCH_dht_roofline.json", "fabric_bytes_per_dev", "<=", 100_000),
]

# -- regression tolerances vs the committed baseline -------------------------
# (artifact, dotted field, direction, rel_tol): "lower" = smaller is better,
# value may grow to baseline*(1+tol); "higher" = larger is better, value may
# shrink to baseline*(1-tol).
REGRESSION = [
    ("BENCH_online_resize.json", "p99_ratio", "lower", 1.0),
    ("BENCH_online_resize.json", "frontend.publish_volume_ratio",
     "lower", 0.5),
    ("BENCH_online_resize.json", "throughput_ratio", "higher", 0.5),
    ("BENCH_batch_parallel.json", "latency_256.insert_fused_vs_scan_p50",
     "higher", 0.5),
    ("BENCH_batch_parallel.json", "latency_256.search_fused_vs_vmap_p50",
     "higher", 0.33),
    ("BENCH_durable_restart.json", "storm.volume_ratio", "lower", 0.5),
    ("BENCH_durable_restart.json", "ttfq_spread", "lower", 0.5),
    ("BENCH_chaos.json", "scrub.bound_ticks", "lower", 0.5),
    ("BENCH_dht_parallel.json", "verify.p99_ratio", "lower", 1.0),
    ("BENCH_dht_parallel.json", "splits.speedup", "higher", 0.5),
    ("BENCH_dht_parallel.json", "reopen.ttfq_ratio", "lower", 0.5),
    ("BENCH_dht_roofline.json", "fabric_bytes_per_dev", "lower", 0.5),
]


def _dig(doc: dict, path: str):
    v = doc
    for part in path.split("."):
        if not isinstance(v, dict) or part not in v:
            return None
        v = v[part]
    return v if isinstance(v, (int, float)) and not isinstance(v, bool) \
        else None


def _load_tree(artifact: str):
    p = os.path.join(ROOT, artifact)
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def _load_ref(artifact: str, ref: str):
    r = subprocess.run(["git", "show", f"{ref}:{artifact}"], cwd=ROOT,
                       capture_output=True, text=True)
    if r.returncode != 0:
        return None
    try:
        return json.loads(r.stdout)
    except json.JSONDecodeError:
        return None


def check_gates(docs: dict) -> list:
    fails = []
    for artifact, field, op, bound in GATES:
        doc = docs.get(artifact)
        if doc is None:
            continue
        v = _dig(doc, field)
        if v is None or (isinstance(v, float) and math.isnan(v)):
            fails.append(f"{artifact}:{field} missing from artifact")
            continue
        ok = {"<=": v <= bound, ">=": v >= bound, "==": v == bound}[op]
        if not ok:
            fails.append(f"{artifact}:{field} = {v:g} violates {op} {bound:g}")
    return fails


def check_regression(docs: dict, ref: str) -> list:
    fails = []
    for artifact, field, direction, tol in REGRESSION:
        doc = docs.get(artifact)
        if doc is None:
            continue
        base_doc = _load_ref(artifact, ref)
        if base_doc is None:
            print(f"# {artifact}: no baseline at {ref}, skipping regression")
            continue
        v, b = _dig(doc, field), _dig(base_doc, field)
        if v is None or b is None:
            continue            # field new in this PR: nothing to regress
        if direction == "lower" and v > b * (1 + tol):
            fails.append(f"{artifact}:{field} = {v:g} regressed vs "
                         f"baseline {b:g} (> +{tol:.0%})")
        elif direction == "higher" and v < b * (1 - tol):
            fails.append(f"{artifact}:{field} = {v:g} regressed vs "
                         f"baseline {b:g} (< -{tol:.0%})")
    return fails


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--self", action="store_true", dest="self_only",
                    help="absolute gate bounds only (no git baseline)")
    ap.add_argument("--ref", default="HEAD",
                    help="git ref for the regression baseline (default HEAD)")
    args = ap.parse_args()

    artifacts = sorted({a for a, *_ in GATES} | {a for a, *_ in REGRESSION})
    docs = {}
    for a in artifacts:
        doc = _load_tree(a)
        if doc is None:
            print(f"# {a}: not in working tree, skipping")
        else:
            docs[a] = doc
    if not docs:
        print("no artifacts found; nothing to check")
        return 0

    fails = check_gates(docs)
    if not args.self_only:
        fails += check_regression(docs, args.ref)
    for f in fails:
        print(f"FAIL {f}")
    n_gates = sum(1 for a, *_ in GATES if a in docs)
    print(f"checked {len(docs)} artifacts, {n_gates} gates"
          + ("" if args.self_only else f", baseline {args.ref}")
          + f": {'FAIL' if fails else 'OK'}")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
