#!/usr/bin/env bash
# Single-core CI: run every gate SEQUENTIALLY (the container has one core —
# parallel suites would just thrash each other; see ROADMAP's bench budgets).
#
#   1. tier-1 pytest           (the correctness gate; `slow` marks excluded)
#   2. python -m compileall    (syntax/bytecode sweep over the library)
#   3. benchmarks/run.py --list (driver + every bench module imports cleanly,
#                               artifact freshness report; runs nothing)
#   4. durable smoke           (write -> KILL the process -> reopen in a
#                               fresh process; the persistence contract is
#                               checked across a real process boundary)
#   5. chaos smoke             (one seeded fault schedule: forced torn
#                               persist + bit flips + crash reopen; zero
#                               wrong reads / silent losses, <~30s)
#   6. fused smoke             (batch-256 insert+search through the fused
#                               single-dispatch path, bit-identical to the
#                               scan/vmap references)
#   7. obs smoke               (REPRO_TRACE=1 frontend workload: valid
#                               Chrome-trace JSON, every ack span linked to
#                               its batch/publish/flush, SLO snapshot
#                               populated)
#   8. bench gates             (scripts/check_bench.py --self: committed
#                               BENCH_*.json artifacts still satisfy their
#                               acceptance bounds)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== compileall =="
python -m compileall -q src

echo "== bench registry =="
python -m benchmarks.run --list

echo "== durable smoke (write -> kill -> reopen) =="
SMOKE_DIR="$(mktemp -d)"
trap 'rm -rf "$SMOKE_DIR"' EXIT
# writer: insert + flush acknowledged keys, then DIE without a clean close
# (os._exit skips every destructor — the closest a test gets to kill -9)
python - "$SMOKE_DIR/smoke.pool" <<'PY'
import os, sys
import numpy as np
from repro.core import DashConfig
from repro import persist
t = persist.create(sys.argv[1], DashConfig(max_segments=16, dir_depth_max=8,
                                           num_buckets=16, num_slots=8))
keys = np.unique(np.random.default_rng(0xC1).integers(1, 2**63, 4000,
                                                      np.uint64))[:1500]
t.insert(keys, (np.arange(1500) + 1).astype(np.uint32))
t.flush()
os._exit(0)
PY
# reopener: a fresh process maps the pool, instant-restarts, verifies every
# acknowledged key, then closes cleanly and reopens once more
python - "$SMOKE_DIR/smoke.pool" <<'PY'
import sys
import numpy as np
from repro import persist
t, info = persist.reopen(sys.argv[1])
assert not info["clean"], "writer died dirty; pool must say so"
keys = np.unique(np.random.default_rng(0xC1).integers(1, 2**63, 4000,
                                                      np.uint64))[:1500]
f, v = t.search(keys)
assert f.all(), f"lost {int((~f).sum())} acknowledged keys"
assert (v == np.arange(1500) + 1).all()
t.close()
t2, info2 = persist.reopen(sys.argv[1])
assert info2["clean"]
f2, _ = t2.search(keys[:256])
assert f2.all() and t2.recovered_segments == 0
print(f"durable smoke OK: {int(f.sum())} keys survived the kill")
PY

echo "== chaos smoke (torn persist + bit rot + crash reopen) =="
python - "$SMOKE_DIR" <<'PY'
import sys
from repro.persist import chaos
r = chaos.run_schedule(7, sys.argv[1], min_tears=1, min_flips=3)
assert r.wrong_reads == 0 and r.silent_lost == 0   # run_schedule asserts too
assert r.tears >= 1 and r.flips >= 3 and r.crashes >= 1
print(f"chaos smoke OK: seed={r.seed} ops={r.ops} tears={r.tears} "
      f"flips={r.flips} crashes={r.crashes} reported_lost={r.reported_lost}")
PY

echo "== fused smoke (batch-256 single-dispatch == scan/vmap) =="
python - <<'PY'
import jax, numpy as np
import jax.numpy as jnp
from repro.core import DashConfig, engine, hashing, layout
cfg = DashConfig(max_segments=16, dir_depth_max=8)
keys = np.unique(np.random.default_rng(0xF5).integers(1, 2**63, 1200,
                                                      np.uint64))[:512]
hi, lo = hashing.np_split_keys(keys)
hi, lo = jnp.asarray(hi), jnp.asarray(lo)
vals = jnp.asarray(np.arange(512, dtype=np.uint32) + 1)
s_scan = layout.make_state(cfg, "eh")
s_fus = jax.tree.map(jnp.copy, s_scan)
for i in range(0, 512, 256):        # two fused batch-256 insert dispatches
    sl = slice(i, i + 256)
    s_scan, st1, _ = engine.insert_batch(cfg, "eh", s_scan, hi[sl], lo[sl],
                                         vals[sl], batching="scan")
    s_fus, st2, _ = engine.insert_batch(cfg, "eh", s_fus, hi[sl], lo[sl],
                                        vals[sl], batching="fused")
    assert (np.asarray(st1) == np.asarray(st2)).all()
for a, b in zip(jax.tree.leaves(s_scan), jax.tree.leaves(s_fus)):
    assert (np.asarray(a) == np.asarray(b)).all()
f1, v1 = engine.search_batch(cfg, "eh", s_scan, hi[:256], lo[:256],
                             batching="vmap")
f2, v2 = engine.search_batch(cfg, "eh", s_fus, hi[:256], lo[:256],
                             batching="fused")
assert np.asarray(f2).all()
assert (np.asarray(f1) == np.asarray(f2)).all()
assert (np.asarray(v1) == np.asarray(v2)).all()
print("fused smoke OK: 512 inserts + 256 searches bit-identical")
PY

echo "== obs smoke (trace capture -> ack linkage + SLO snapshot) =="
REPRO_TRACE=1 python - "$SMOKE_DIR/obs.pool" <<'PY'
import json, sys
import numpy as np
from repro import persist
from repro.persist.chaos import CHAOS_CFG
from repro.serving.frontend import INSERT, READ, DashFrontend, Op
t = persist.create(sys.argv[1], CHAOS_CFG)
f = DashFrontend(t)
assert f.obs.tracer.enabled, "REPRO_TRACE=1 must enable span capture"
keys = np.unique(np.random.default_rng(0x0B5).integers(1, 2**63, 2000,
                                                       np.uint64))[:700]
for k in keys:
    f.submit(Op(INSERT, int(k), int(k & 0x7FFFFFFF)))
for k in keys[:128]:
    f.submit(Op(READ, int(k)))
f.drain()
doc = f.obs.tracer.export_chrome_trace(sys.argv[1] + ".trace.json")
json.load(open(sys.argv[1] + ".trace.json"))     # valid JSON on disk
evs = [e for e in doc["traceEvents"] if e["ph"] in ("X", "i")]
by_sid = {e["args"]["sid"]: e for e in evs}
acks = [e for e in evs if e["name"] == "ack"]
assert acks, "no ack spans recorded"
for a in acks:
    names = {by_sid[l]["name"] for l in a["args"].get("links", [])
             if l in by_sid}
    want = ({"write_batch", "publish", "flush"}
            if a["args"].get("kind") == INSERT else {"read_batch"})
    assert want <= names, (a["args"], names)
snap = f.obs_snapshot()
assert snap["slo"]["tick"] > 0 and "read_sojourn" in snap["slo"]
assert snap["metrics"]["frontend.write_sojourn_s"]["n"] == len(keys)
n_flush = sum(1 for e in evs if e["name"] == "flush")
print(f"obs smoke OK: {len(acks)} acks linked, {n_flush} flush spans, "
      f"slo ticks={snap['slo']['tick']}")
PY

echo "== dht smoke (8-shard write -> kill -> lazy reopen -> serve) =="
# writer: 8 fake devices, one durable pool per shard, flush, then DIE dirty
XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \
python - "$SMOKE_DIR/dht_shards" <<'PY'
import os, sys
import numpy as np
from repro import persist
from repro.core import DashConfig
from repro.distributed import DistributedDash
from repro.launch.mesh import make_test_mesh
cfg = DashConfig(max_segments=32, dir_depth_max=8)
d = DistributedDash(cfg, make_test_mesh(2, 4), axes=("data", "model"),
                    capacity=256)
d.attach_pools(persist.create_shard_pools(sys.argv[1], cfg, d.n_shards))
keys = np.unique(np.random.default_rng(0xD1).integers(1, 2**63, 6000,
                                                      np.uint64))[:2000]
st = d.insert(keys, (np.arange(2000) + 1).astype(np.uint32))
assert (st == 0).all()
d.flush_pools()
os._exit(0)
PY
# reopener: lazy default (eager_recover_dirty=False) -> O(1) reopen; the
# first served reads must trigger per-access recovery, and the frontend's
# obs snapshot must carry the aggregated per-shard registries
XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \
python - "$SMOKE_DIR/dht_shards" <<'PY'
import sys
import numpy as np
from repro import persist
from repro.core import DashConfig
from repro.distributed import DistributedDash, ShardFrontend
from repro.launch.mesh import make_test_mesh
from repro.serving.frontend import Op, READ
cfg = DashConfig(max_segments=32, dir_depth_max=8)
stacked, wbs, info = persist.reopen_shards(sys.argv[1])
assert info["dirty_shards"] == 8, info   # writer died dirty, no eager work
d = DistributedDash(cfg, make_test_mesh(2, 4), axes=("data", "model"),
                    capacity=256, state=stacked)
d.attach_pools(wbs)
fe = ShardFrontend(d, max_batch=256)
assert d.recovered_segments == 0         # nothing recovered before access
keys = np.unique(np.random.default_rng(0xD1).integers(1, 2**63, 6000,
                                                      np.uint64))[:2000]
ops = [Op(READ, int(k)) for k in keys[:512]]
for op in ops:
    assert fe.submit(op)
fe.drain()
assert all(op.found and op.result == i + 1 for i, op in enumerate(ops))
assert d.recovered_segments > 0, "lazy recovery never fired on first access"
snap = fe.obs_snapshot()
agg = snap["shards"]["shard.read_sojourn_s"]
assert agg["n"] == 512, agg              # fleet view sums per-shard regs
assert len(snap["per_shard"]) == 8
print(f"dht smoke OK: 512 reads served, "
      f"{d.recovered_segments} segments lazily recovered")
PY

echo "== bench gates (committed artifacts satisfy acceptance bounds) =="
python scripts/check_bench.py --self

echo "CI OK"
