#!/usr/bin/env bash
# Single-core CI: run every gate SEQUENTIALLY (the container has one core —
# parallel suites would just thrash each other; see ROADMAP's bench budgets).
#
#   1. tier-1 pytest           (the correctness gate; `slow` marks excluded)
#   2. python -m compileall    (syntax/bytecode sweep over the library)
#   3. benchmarks/run.py --list (driver + every bench module imports cleanly,
#                               artifact freshness report; runs nothing)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== compileall =="
python -m compileall -q src

echo "== bench registry =="
python -m benchmarks.run --list

echo "CI OK"
