#!/usr/bin/env bash
# Single-core CI: run every gate SEQUENTIALLY (the container has one core —
# parallel suites would just thrash each other; see ROADMAP's bench budgets).
#
#   1. tier-1 pytest           (the correctness gate; `slow` marks excluded)
#   2. python -m compileall    (syntax/bytecode sweep over the library)
#   3. benchmarks/run.py --list (driver + every bench module imports cleanly,
#                               artifact freshness report; runs nothing)
#   4. durable smoke           (write -> KILL the process -> reopen in a
#                               fresh process; the persistence contract is
#                               checked across a real process boundary)
#   5. chaos smoke             (one seeded fault schedule: forced torn
#                               persist + bit flips + crash reopen; zero
#                               wrong reads / silent losses, <~30s)
#   6. fused smoke             (batch-256 insert+search through the fused
#                               single-dispatch path, bit-identical to the
#                               scan/vmap references)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== compileall =="
python -m compileall -q src

echo "== bench registry =="
python -m benchmarks.run --list

echo "== durable smoke (write -> kill -> reopen) =="
SMOKE_DIR="$(mktemp -d)"
trap 'rm -rf "$SMOKE_DIR"' EXIT
# writer: insert + flush acknowledged keys, then DIE without a clean close
# (os._exit skips every destructor — the closest a test gets to kill -9)
python - "$SMOKE_DIR/smoke.pool" <<'PY'
import os, sys
import numpy as np
from repro.core import DashConfig
from repro import persist
t = persist.create(sys.argv[1], DashConfig(max_segments=16, dir_depth_max=8,
                                           num_buckets=16, num_slots=8))
keys = np.unique(np.random.default_rng(0xC1).integers(1, 2**63, 4000,
                                                      np.uint64))[:1500]
t.insert(keys, (np.arange(1500) + 1).astype(np.uint32))
t.flush()
os._exit(0)
PY
# reopener: a fresh process maps the pool, instant-restarts, verifies every
# acknowledged key, then closes cleanly and reopens once more
python - "$SMOKE_DIR/smoke.pool" <<'PY'
import sys
import numpy as np
from repro import persist
t, info = persist.reopen(sys.argv[1])
assert not info["clean"], "writer died dirty; pool must say so"
keys = np.unique(np.random.default_rng(0xC1).integers(1, 2**63, 4000,
                                                      np.uint64))[:1500]
f, v = t.search(keys)
assert f.all(), f"lost {int((~f).sum())} acknowledged keys"
assert (v == np.arange(1500) + 1).all()
t.close()
t2, info2 = persist.reopen(sys.argv[1])
assert info2["clean"]
f2, _ = t2.search(keys[:256])
assert f2.all() and t2.recovered_segments == 0
print(f"durable smoke OK: {int(f.sum())} keys survived the kill")
PY

echo "== chaos smoke (torn persist + bit rot + crash reopen) =="
python - "$SMOKE_DIR" <<'PY'
import sys
from repro.persist import chaos
r = chaos.run_schedule(7, sys.argv[1], min_tears=1, min_flips=3)
assert r.wrong_reads == 0 and r.silent_lost == 0   # run_schedule asserts too
assert r.tears >= 1 and r.flips >= 3 and r.crashes >= 1
print(f"chaos smoke OK: seed={r.seed} ops={r.ops} tears={r.tears} "
      f"flips={r.flips} crashes={r.crashes} reported_lost={r.reported_lost}")
PY

echo "== fused smoke (batch-256 single-dispatch == scan/vmap) =="
python - <<'PY'
import jax, numpy as np
import jax.numpy as jnp
from repro.core import DashConfig, engine, hashing, layout
cfg = DashConfig(max_segments=16, dir_depth_max=8)
keys = np.unique(np.random.default_rng(0xF5).integers(1, 2**63, 1200,
                                                      np.uint64))[:512]
hi, lo = hashing.np_split_keys(keys)
hi, lo = jnp.asarray(hi), jnp.asarray(lo)
vals = jnp.asarray(np.arange(512, dtype=np.uint32) + 1)
s_scan = layout.make_state(cfg, "eh")
s_fus = jax.tree.map(jnp.copy, s_scan)
for i in range(0, 512, 256):        # two fused batch-256 insert dispatches
    sl = slice(i, i + 256)
    s_scan, st1, _ = engine.insert_batch(cfg, "eh", s_scan, hi[sl], lo[sl],
                                         vals[sl], batching="scan")
    s_fus, st2, _ = engine.insert_batch(cfg, "eh", s_fus, hi[sl], lo[sl],
                                        vals[sl], batching="fused")
    assert (np.asarray(st1) == np.asarray(st2)).all()
for a, b in zip(jax.tree.leaves(s_scan), jax.tree.leaves(s_fus)):
    assert (np.asarray(a) == np.asarray(b)).all()
f1, v1 = engine.search_batch(cfg, "eh", s_scan, hi[:256], lo[:256],
                             batching="vmap")
f2, v2 = engine.search_batch(cfg, "eh", s_fus, hi[:256], lo[:256],
                             batching="fused")
assert np.asarray(f2).all()
assert (np.asarray(f1) == np.asarray(f2)).all()
assert (np.asarray(v1) == np.asarray(v2)).all()
print("fused smoke OK: 512 inserts + 256 searches bit-identical")
PY

echo "CI OK"
